"""Fig. 3 analog: recall of vanilla's top-k within centroid-ONLY retrieval
at depth k' ∈ {k, 2k, 5k, 10k} — validates the paper's core hypothesis that
centroids alone identify the strong candidates (§3.3)."""
from __future__ import annotations

import dataclasses

from repro.core import plaid, vanilla

from benchmarks import common

N_DOCS = 4000


def run(emit):
    docs, index = common.corpus_and_index(N_DOCS)
    qs, _ = common.queries(docs, 48)
    for k in (10, 100):
        vs = vanilla.VanillaSearcher(
            index, vanilla.VanillaParams(k=k, nprobe=4, ncandidates=2**13)
        )
        _, v_pids = vs.search_batch(qs)
        for mult in (1, 2, 5, 10):
            kp = k * mult
            # centroid-only: no pruning, final ranking by stage-3 scores only
            # (ndocs=4*kp so stage 3 emits kp candidates; stage 4 re-ranks
            # within them, set membership is centroid-determined)
            sp = dataclasses.replace(
                plaid.params_for_k(kp),
                nprobe=4,
                t_cs=-1e9,
                ndocs=4 * kp,
                candidate_cap=8192,
            )
            ps = plaid.PlaidSearcher(index, sp)
            _, c_pids = ps.search_batch(qs)
            import numpy as np

            recall = float(
                np.mean(
                    [
                        len(set(np.asarray(v)) & set(np.asarray(c)[:kp])) / k
                        for v, c in zip(v_pids, c_pids)
                    ]
                )
            )
            emit("fig3", f"k{k}_depth{mult}k", recall=round(recall, 4))
