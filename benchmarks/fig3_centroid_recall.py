"""Fig. 3 analog: recall of vanilla's top-k within centroid-ONLY retrieval
at depth k' ∈ {k, 2k, 5k, 10k} — validates the paper's core hypothesis that
centroids alone identify the strong candidates (§3.3).  Engines come from
the ``repro.retrieval`` registry."""
from __future__ import annotations

import numpy as np

from repro import retrieval

from benchmarks import common

N_DOCS = 4000


def run(emit, dry: bool = False):
    docs, index = common.corpus_and_index(common.scaled(N_DOCS, dry, 500))
    qs, _ = common.queries(docs, common.scaled(48, dry, 8))
    for k in (10, 100):
        vr = retrieval.from_index(
            index,
            backend="vanilla",
            params=retrieval.SearchParams(
                k=k, nprobe=4, candidate_cap=2**13, ndocs=4096
            ),
        )
        v_pids = vr.search_batch(qs).pids
        for mult in (1, 2, 5, 10):
            kp = k * mult
            # centroid-only: no pruning, final ranking by stage-3 scores only
            # (ndocs=4*kp so stage 3 emits kp candidates; stage 4 re-ranks
            # within them, set membership is centroid-determined)
            pr = retrieval.from_index(
                index,
                backend="plaid",
                params=retrieval.params_for_k(kp).replace(
                    nprobe=4, t_cs=-1e9, ndocs=4 * kp, candidate_cap=8192
                ),
            )
            c_pids = pr.search_batch(qs).pids
            recall = float(
                np.mean(
                    [
                        len(set(np.asarray(v)) & set(np.asarray(c)[:kp])) / k
                        for v, c in zip(v_pids, c_pids)
                    ]
                )
            )
            emit("fig3", f"k{k}_depth{mult}k", recall=round(recall, 4))
