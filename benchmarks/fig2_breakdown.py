"""Fig. 2 analog: per-stage latency breakdown, vanilla vs PLAID.

The paper's headline diagnosis: vanilla ColBERTv2 spends its time in index
lookup + residual decompression; PLAID's centroid stages eliminate most of
it.  Stage timings come from recorded ``repro.obs`` tracer spans (the same
spans ``--trace`` exports as Chrome trace JSON), not ad-hoc timer pairs,
and the funnel telemetry (``run_pipeline(..., funnel=True)``) reports the
candidate counts each stage actually saw — the paper's funnel figure next
to its latency figure.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import retrieval
from repro.core import plaid, scoring
from repro.core import residual_codec as rc
from repro.obs.trace import get_tracer

from benchmarks import common

N_DOCS = 8000


def _timed(tracer, name, fn, *args, reps=20, **attrs):
    """Mean ms over ``reps`` recorded spans (compile excluded: one warmup
    call runs before the first span opens)."""
    jax.block_until_ready(fn(*args))
    for _ in range(reps):
        with tracer.span(name, **attrs):
            jax.block_until_ready(fn(*args))
    durs = tracer.durations_ms(name)[-reps:]
    return sum(durs) / len(durs)


def run(emit, dry: bool = False):
    tracer = get_tracer()
    docs, index = common.corpus_and_index(common.scaled(N_DOCS, dry, 500))
    qs, _ = common.queries(docs, 8)
    q, q_mask = qs[0], jnp.ones(qs.shape[1])
    # the facade's params are the single source of stage settings; this bench
    # times the pipeline's internals, so it unpacks them below
    p = retrieval.params_for_k(100)
    cap = min(p.candidate_cap, index.num_passages)
    reps = 5 if dry else 20

    # ---- PLAID stages
    s1 = jax.jit(
        lambda q: plaid.candidate_generation(
            index, scoring.centroid_scores(q, index.centroids), p.nprobe, cap
        )
    )
    t1 = _timed(tracer, "fig2.stage1_candidates", s1, q, reps=reps)
    cands = s1(q)

    def stage23(q, cands):
        s_cq = scoring.centroid_scores(q, index.centroids)
        keep = scoring.prune_mask(s_cq, p.t_cs)
        codes_blk, tok_valid = scoring.gather_doc_tokens(
            index.codes, index.doc_offsets, index.doc_lens, cands,
            index.doc_maxlen, fill=-1,
        )
        a2 = scoring.centroid_interaction(s_cq, codes_blk, q_mask, keep)
        _, idx2 = jax.lax.top_k(a2, min(p.ndocs, cap))
        a3 = scoring.centroid_interaction(s_cq, codes_blk[idx2], q_mask)
        _, idx3 = jax.lax.top_k(a3, max(p.ndocs // 4, p.k))
        return cands[idx2][idx3]

    s23 = jax.jit(stage23)
    t23 = _timed(tracer, "fig2.stage23_interaction", s23, q, cands, reps=reps)
    final = s23(q, cands)

    def stage4(q, final):
        codes_blk, tok_valid = scoring.gather_doc_tokens(
            index.codes, index.doc_offsets, index.doc_lens, final,
            index.doc_maxlen, fill=-1,
        )
        res_blk, _ = scoring.gather_doc_tokens(
            index.residuals, index.doc_offsets, index.doc_lens, final,
            index.doc_maxlen, fill=jnp.uint8(0),
        )
        return plaid.decompress_and_score_ref(
            index, q, q_mask, codes_blk, res_blk, tok_valid
        )

    t4 = _timed(
        tracer, "fig2.stage4_decompress_score", jax.jit(stage4), q, final,
        reps=reps,
    )
    emit("fig2", "plaid_stage1_candidates", ms=round(t1, 3))
    emit("fig2", "plaid_stage23_interaction", ms=round(t23, 3))
    emit("fig2", "plaid_stage4_decompress_score", ms=round(t4, 3))

    # ---- vanilla: lookup+decompress of the big embedding candidate set
    nc = min(2**13, index.num_tokens)

    def vanilla_lookup_decompress(q):
        s_cq = scoring.centroid_scores(q, index.centroids)
        _, cids = jax.lax.top_k(s_cq.T, 4)
        starts = index.eivf_offsets[cids.reshape(-1)]
        lens = index.eivf_lens[cids.reshape(-1)]
        pos = jnp.arange(index.eivf_list_cap, dtype=jnp.int32)
        idx = jnp.where(pos[None] < lens[:, None], starts[:, None] + pos[None], 0)
        eids = jnp.unique(
            jnp.where(pos[None] < lens[:, None], index.eivf_eids[idx], -1).reshape(-1),
            size=nc, fill_value=-1,
        )
        safe = jnp.where(eids >= 0, eids, 0)
        return rc.decompress(
            index.codec, index.codes[safe], index.residuals[safe], index.centroids
        )

    tv = _timed(
        tracer, "fig2.vanilla_lookup_decompress",
        jax.jit(vanilla_lookup_decompress), q, reps=reps,
    )
    emit("fig2", "vanilla_lookup_decompress", ms=round(tv, 3),
         note="the paper's Fig2a bottleneck PLAID removes")

    # ---- the funnel the latency bars explain: per-stage candidate counts
    # from the in-graph FunnelStats aux (mean over the query batch)
    import dataclasses

    import numpy as np

    from repro.core import pipeline
    from repro.kernels import costs
    from repro.retrieval import backends

    B = 4
    qs_b = qs[:B] if qs.shape[0] >= B else jnp.tile(qs, (B, 1, 1))[:B]
    masks_b = jnp.ones(qs_b.shape[:2], jnp.float32)
    core_p = plaid.clamp_params(
        backends.to_engine_params(p, impl="pallas"), index.num_passages
    )
    _, _, fstats = pipeline.run_pipeline(
        index, qs_b, masks_b, p.t_cs, core_p, funnel=True
    )
    emit("fig2", "funnel", **{
        name: round(float(np.asarray(v).mean()), 1)
        for name, v in zip(type(fstats)._fields, fstats)
    })

    # ---- fused vs unfused stage-3-5 tail: the per-stage layout above no
    # longer describes the fused pipeline (one megakernel replaces gather +
    # decompress + maxsim), so the comparison is end-to-end batched
    # run_pipeline timings plus the analytic bytes the fusion removes.
    for fused in (False, True):
        pp = dataclasses.replace(core_p, fused=fused)
        t = _timed(
            tracer, f"fig2.pipeline_B{B}_{'fused' if fused else 'unfused'}",
            lambda qs_, m: pipeline.run_pipeline(index, qs_, m, p.t_cs, pp),
            qs_b, masks_b, reps=5 if dry else 20,
        )
        emit("fig2", f"pipeline_B{B}_{'fused' if fused else 'unfused'}",
             ms=round(t, 3))
    n2 = min(core_p.ndocs, core_p.candidate_cap)
    n3 = min(max(core_p.ndocs // 4, core_p.k), n2)
    geom = dict(
        B=B, n3=n3, L=index.doc_maxlen,
        pd=int(np.asarray(index.residuals).shape[1]),
        K=index.num_centroids, d=index.dim, nq=int(qs_b.shape[1]),
        nbits=index.nbits,
    )
    fb = costs.fused_stage345_cost(**geom)["hbm_bytes"]
    ub = costs.unfused_stage345_cost(**geom)["hbm_bytes"]
    emit("fig2", f"stage345_bytes_B{B}", fused_hbm_bytes=int(fb),
         unfused_hbm_bytes=int(ub),
         bytes_saved_ratio=round(1.0 - fb / ub, 4))
