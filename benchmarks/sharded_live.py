"""Sharded live serving benchmark: latency vs shard count x delta count.

The ``"live-sharded"`` composition (``repro.exec``: base segment sharded
over the mesh, delta segments replicated, one shared top-k merge) trades
three costs this sweep separates:

1. **Shard speedup on the base** — each device searches 1/n of the corpus;
   at laptop scale (fake host devices) the win is bounded by dispatch
   overhead, so read trends, not absolutes.
2. **Delta drag** — replicated deltas add one stacked-pipeline launch and
   widen the final merge; the sweep holds the TOTAL corpus fixed and only
   varies segmentation, isolating that overhead.
3. **One-trace discipline** — the stacked delta program compiles once per
   segment-count bucket; ``traces`` in the output counts pipeline
   (re)compiles across the whole row and should stay flat within a bucket.

Shard counts are limited by the visible device count: run under
``XLA_FLAGS=--xla_force_host_platform_device_count=4`` (the
``make test-multidevice`` environment) to sweep the multi-shard points.
"""
from __future__ import annotations

import jax
import numpy as np

from repro import live
from repro.core import index as index_mod, pipeline, plaid
from repro.data import synthetic as syn

from benchmarks import common

N_TOTAL = 8000
CHUNK = 256  # docs per delta segment
SHARD_COUNTS = (1, 2, 4)
DELTA_COUNTS = (0, 1, 3)
NUM_CENTROIDS = 2048


def _segmented_live(docs, n_deltas, chunk, num_centroids):
    """Same total corpus, segmented as base + n_deltas chunks."""
    n_base = len(docs) - n_deltas * chunk
    base = index_mod.build_index(
        docs[:n_base], num_centroids=num_centroids, kmeans_iters=4
    )
    lv = live.LiveIndex(base)
    for i in range(n_deltas):
        lv.add_passages(docs[n_base + i * chunk : n_base + (i + 1) * chunk])
    return lv


def run(emit, dry: bool = False):
    n_total = common.scaled(N_TOTAL, dry, 360)
    chunk = common.scaled(CHUNK, dry, 24)
    num_centroids = 256 if dry else NUM_CENTROIDS
    trials = 1 if dry else 3
    batch = 4 if dry else 16
    n_queries = 8 if dry else 64
    shard_counts = [s for s in SHARD_COUNTS if s <= len(jax.devices())]
    if len(shard_counts) < len(SHARD_COUNTS):
        print(
            f"# sharded_live: only {len(jax.devices())} device(s) visible; "
            f"sweeping shards={shard_counts} (force more via XLA_FLAGS)"
        )

    docs, _ = syn.embedding_corpus(n_total, dim=128, seed=0)
    qs, _ = common.queries(docs, n_queries)
    params = plaid.SearchParams(
        k=10, nprobe=2, t_cs=0.45, ndocs=256, candidate_cap=1024
    )

    for n_deltas in DELTA_COUNTS:
        lv = _segmented_live(docs, n_deltas, chunk, num_centroids)
        for n_shards in shard_counts:
            eng = live.LiveEngine(lv, params, n_shards=n_shards)
            t0 = pipeline.trace_count()
            ms = common.time_batched(
                lambda q: eng.search_batch(q), qs, batch=batch, trials=trials
            )
            emit(
                "sharded_live",
                f"shards{n_shards}_deltas{n_deltas}",
                n_docs=n_total,
                n_shards=n_shards,
                n_deltas=n_deltas,
                ms_per_query=round(ms, 3),
                traces=pipeline.trace_count() - t0,
            )
