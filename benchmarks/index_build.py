"""Index-build benchmark: streaming two-pass vs monolithic, memory + speed.

What the streaming builder (``repro.build``) buys is a HOST-MEMORY bound,
not single-box speed: the monolithic ``build_index`` holds every token
embedding in one float32 array (4·Nt·d bytes), the streaming builder holds
``sample + one chunk`` regardless of corpus size.  This benchmark reports,
per corpus size:

* build throughput (tokens/s) for both paths and the streaming/monolithic
  time ratio (the two-pass + chunking overhead);
* the builder's peak float32 materialization (``BuildStats``) vs the
  monolithic path's full-corpus array — the memory-bound headline;
* process peak RSS (``ru_maxrss``) for reference — monotonic across cases,
  so read per-case deltas with care;
* a device sweep (1 .. all visible devices) of the mesh-parallel pass-1 /
  row-sharded pass-2 build.  On fake host devices (one physical core) the
  wall-clock win is bounded by dispatch overhead — read trends on real
  meshes, and bit-identity here (asserted in tests, reported as
  ``identical``).
"""
from __future__ import annotations

import resource
import time

import jax
import numpy as np

from repro.build import StreamingIndexBuilder
from repro.core import index as index_mod
from repro.data import synthetic as syn

from benchmarks import common

SIZES = (2000, 8000)
CHUNK_DOCS = 256
NUM_CENTROIDS = 1024
KMEANS_ITERS = 4
SAMPLE_SIZE = 1 << 15


def _rss_mb() -> float:
    return resource.getrusage(resource.RUSAGE_SELF).ru_maxrss / 1024.0


def run(emit, dry: bool = False):
    sizes = [common.scaled(n, dry, 200) for n in SIZES]
    num_centroids = 128 if dry else NUM_CENTROIDS
    sample = 2048 if dry else SAMPLE_SIZE
    chunk_docs = common.scaled(CHUNK_DOCS, dry, 32)

    for n_docs in sizes:
        docs, _ = syn.embedding_corpus(n_docs, dim=128, seed=0)
        packed = np.concatenate(docs)
        n_tokens = packed.shape[0]
        corpus_f32_bytes = packed.nbytes

        t0 = time.perf_counter()
        index_mod.build_index(
            docs, num_centroids=num_centroids, kmeans_iters=KMEANS_ITERS
        )
        t_mono = time.perf_counter() - t0

        builder = StreamingIndexBuilder(
            num_centroids=num_centroids,
            kmeans_iters=KMEANS_ITERS,
            sample_size=sample,
            chunk_docs=chunk_docs,
        )
        t0 = time.perf_counter()
        builder.build(docs)
        t_stream = time.perf_counter() - t0
        st = builder.stats

        emit(
            "index_build",
            f"docs{n_docs}",
            n_tokens=n_tokens,
            mono_s=round(t_mono, 3),
            stream_s=round(t_stream, 3),
            mono_tokens_per_s=int(n_tokens / max(t_mono, 1e-9)),
            stream_tokens_per_s=int(n_tokens / max(t_stream, 1e-9)),
            stream_over_mono=round(t_stream / max(t_mono, 1e-9), 2),
            corpus_f32_mb=round(corpus_f32_bytes / 2**20, 2),
            builder_peak_f32_mb=round(st.peak_host_f32_bytes / 2**20, 2),
            mem_bound_ratio=round(
                st.peak_host_f32_bytes / max(corpus_f32_bytes, 1), 3
            ),
            sample_tokens=st.sample_tokens,
            n_chunks=st.n_chunks,
            rss_mb=round(_rss_mb(), 1),
        )

    # device sweep: mesh-parallel pass 1 + row-sharded pass 2.  Output is
    # bit-identical across counts by construction (tests assert it); here
    # we track the wall-clock trend.
    n_docs = sizes[0]
    docs, _ = syn.embedding_corpus(n_docs, dim=128, seed=0)
    # largest device count the default block granularity supports (an
    # odd visible count — 3, 6 — must not abort the whole bench run)
    from repro.build import DEFAULT_STAT_BLOCKS

    usable = max(
        d
        for d in range(1, len(jax.devices()) + 1)
        if DEFAULT_STAT_BLOCKS % d == 0
    )
    counts = sorted({1, usable})
    if len(counts) == 1:
        print(
            "# index_build: single visible device — run under XLA_FLAGS="
            "--xla_force_host_platform_device_count=4 for the mesh sweep"
        )
    for n_dev in counts:
        builder = StreamingIndexBuilder(
            num_centroids=num_centroids,
            kmeans_iters=KMEANS_ITERS,
            sample_size=sample,
            chunk_docs=chunk_docs,
            n_devices=n_dev,
        )
        t0 = time.perf_counter()
        builder.build(docs)
        emit(
            "index_build",
            f"mesh_dev{n_dev}",
            n_devices=n_dev,
            build_s=round(time.perf_counter() - t0, 3),
            pass1_s=round(builder.stats.pass1_s, 3),
            pass2_s=round(builder.stats.pass2_s, 3),
        )
