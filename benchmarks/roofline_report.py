"""Render the dry-run JSONL into the EXPERIMENTS.md roofline tables.

  PYTHONPATH=src python -m benchmarks.roofline_report results/dryrun.jsonl

Also registered in ``benchmarks.run`` (``--only roofline``): ``run(emit)``
lowers the batch-first retrieval pipeline itself and pushes the optimized
HLO through ``repro.launch.hlo_analysis`` — per-batch-size flops / HBM
bytes / dot counts and the roofline-dominant term, without touching a
results file.
"""
from __future__ import annotations

import json
import sys


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("TB", 1e12), ("GB", 1e9), ("MB", 1e6), ("KB", 1e3)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(path):
    recs = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if line:
                recs.append(json.loads(line))
    # keep the LAST record per (arch, shape, mesh) — reruns supersede
    dedup = {}
    for r in recs:
        dedup[(r["arch"], r["shape"], r["mesh"])] = r
    return list(dedup.values())


def table(recs, mesh):
    rows = [r for r in recs if r["mesh"] == mesh]
    out = [
        "| arch | shape | status | mem/dev | compute | memory | collective |"
        " dominant | useful | roofline |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        if r["status"] == "skip":
            out.append(
                f"| {r['arch']} | {r['shape']} | SKIP | - | - | - | - | - | - | - |"
            )
            continue
        if r["status"] != "ok":
            out.append(
                f"| {r['arch']} | {r['shape']} | **FAIL** | - | - | - | - | - | - | - |"
            )
            continue
        mem = (r.get("mem_args") or 0) + (r.get("mem_temp") or 0) - (
            r.get("mem_alias") or 0
        )
        out.append(
            "| {arch} | {shape} | ok | {mem} | {c} | {m} | {x} | {dom} |"
            " {useful:.2f} | {rf:.3f} |".format(
                arch=r["arch"], shape=r["shape"], mem=fmt_b(mem),
                c=fmt_s(r["compute_s"]), m=fmt_s(r["memory_s"]),
                x=fmt_s(r["collective_s"]), dom=r["dominant"],
                useful=r.get("useful_ratio", 0),
                rf=r.get("roofline_fraction", 0),
            )
        )
    return "\n".join(out)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun_baseline.jsonl"
    recs = load(path)
    ok = sum(1 for r in recs if r["status"] == "ok")
    skip = sum(1 for r in recs if r["status"] == "skip")
    fail = sum(1 for r in recs if r["status"] == "fail")
    print(f"## Dry-run summary: {ok} ok / {skip} skip / {fail} fail\n")
    for mesh in ("16x16", "2x16x16"):
        print(f"### Mesh {mesh}\n")
        print(table(recs, mesh))
        print()
    if fail:
        print("### Failures\n")
        for r in recs:
            if r["status"] == "fail":
                print(f"- {r['arch']}/{r['shape']}/{r['mesh']}: {r['error'][:300]}")


def run(emit, dry: bool = False):
    """Cost-model the batched retrieval pipeline (HLO roofline analysis)."""
    import jax.numpy as jnp
    import numpy as np

    from repro.core import pipeline, plaid
    from repro.launch import hlo_analysis

    from benchmarks import common

    docs, index = common.corpus_and_index(common.scaled(4000, dry, 200))
    params = plaid.PlaidEngine(index, plaid.params_for_k(10))._pipeline_params()
    dim = index.dim
    nq = 16
    rng = np.random.default_rng(0)
    for B in (1, 8) if dry else (1, 8, 32):
        qs = jnp.asarray(rng.normal(size=(B, nq, dim)).astype(np.float32))
        lowered = pipeline.run_pipeline_jit.lower(
            index, qs, jnp.ones((B, nq), jnp.float32), jnp.float32(0.45),
            params=params,
        )
        cost = hlo_analysis.analyze(lowered.compile().as_text())
        # useful flops: the stage-1 batch matmul + stage-4 exact MaxSim
        n3 = min(max(params.ndocs // 4, params.k), params.ndocs)
        model_flops = (
            2.0 * index.num_centroids * dim * B * nq
            + 2.0 * B * n3 * index.doc_maxlen * dim * nq
        )
        terms = hlo_analysis.roofline_terms(
            per_chip_flops=cost.flops,
            per_chip_bytes=cost.hbm_bytes,
            per_chip_coll_bytes=cost.coll_bytes,
            model_flops=model_flops,
            n_chips=1,
        )
        emit(
            "roofline_pipeline",
            f"B{B}",
            batch=B,
            dots=cost.dot_count,
            hlo_gflops=round(cost.flops / 1e9, 3),
            hbm_mb=round(cost.hbm_bytes / 1e6, 1),
            dominant=terms.dominant,
            useful_ratio=round(terms.useful_ratio, 3),
        )

    # ---- per-kernel analytic traffic (CI-gated via benchmarks.bench_diff)
    # Shape arithmetic over each kernel's actual (grid, block, index_map)
    # triple (repro.kernels.costs): deterministic across machines and jax
    # versions — unlike the HLO-derived hbm_mb above — so these hbm_bytes
    # records carry the hard >15% regression gate.
    from repro.kernels import costs

    L = index.doc_maxlen
    pd = int(np.asarray(index.residuals).shape[1])
    K_, d_ = index.num_centroids, index.dim
    n2 = min(params.ndocs, params.candidate_cap)
    n3 = min(max(params.ndocs // 4, params.k), n2)
    for B in (1, 8) if dry else (1, 8, 32):
        geom = dict(B=B, L=L, pd=pd, K=K_, d=d_, nq=nq, nbits=index.nbits)
        ci = costs.centroid_interaction_batched_cost(
            B=B, nd=params.candidate_cap, L=L, K=K_, nq=nq
        )
        ds = costs.decompress_and_score_batched_cost(nd=n3, **geom)
        fused = costs.fused_stage345_cost(n3=n3, **geom)
        unfused = costs.unfused_stage345_cost(n3=n3, **geom)
        emit("kernel_bytes", f"centroid_interaction_B{B}",
             hbm_bytes=int(ci["hbm_bytes"]), flops=int(ci["flops"]))
        emit("kernel_bytes", f"decompress_score_B{B}",
             hbm_bytes=int(ds["hbm_bytes"]), flops=int(ds["flops"]))
        emit("kernel_bytes", f"fused_stage345_B{B}",
             hbm_bytes=int(fused["hbm_bytes"]), flops=int(fused["flops"]))
        emit("kernel_bytes", f"unfused_stage345_B{B}",
             hbm_bytes=int(unfused["hbm_bytes"]), flops=int(unfused["flops"]))
        emit(
            "kernel_bytes",
            f"fused_vs_unfused_B{B}",
            fused_hbm_bytes=int(fused["hbm_bytes"]),
            unfused_hbm_bytes=int(unfused["hbm_bytes"]),
            bytes_saved_ratio=round(
                1.0 - fused["hbm_bytes"] / unfused["hbm_bytes"], 4
            ),
        )


if __name__ == "__main__":
    main()
