"""Shared benchmark fixtures: corpus/index/query construction + timing."""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_mod
from repro.data import synthetic as syn


@functools.lru_cache(maxsize=4)
def corpus_and_index(n_docs: int, dim: int = 128, nbits: int = 2, seed: int = 0):
    docs, _ = syn.embedding_corpus(n_docs, dim=dim, seed=seed)
    index = index_mod.build_index(docs, nbits=nbits, kmeans_iters=4, seed=seed)
    return docs, index


def queries(docs, n: int, q_len: int = 16, seed: int = 1):
    qs, gold = syn.queries_from_docs(docs, n, q_len=q_len, seed=seed)
    return jnp.asarray(qs), gold


def scaled(n: int, dry: bool, floor: int = 1) -> int:
    """Dry-run scaling: ~1/16 of the configured size, at least ``floor``."""
    return max(floor, n // 16) if dry else n


def time_batched(fn, qs, batch: int = 16, trials: int = 3):
    """Paper protocol: average per-query latency, min over trials."""
    fn(qs[:batch])  # warmup/compile
    jax.block_until_ready(fn(qs[:batch]))
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for i in range(0, qs.shape[0], batch):
            out = fn(qs[i : i + batch])
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / qs.shape[0])
    return best * 1e3  # ms/query


def success_at_1(pids, gold) -> float:
    return float((np.asarray(pids)[:, 0] == gold).mean())


def recall_vs(pids, ref_pids, k: int) -> float:
    return float(
        np.mean(
            [
                len(set(np.asarray(p)[:k]) & set(np.asarray(r)[:k])) / k
                for p, r in zip(pids, ref_pids)
            ]
        )
    )
