"""Shared benchmark fixtures: corpus/index/query construction + timing.

Index construction is the dominant fixture cost, and the four CI jobs
each rebuilt it from scratch.  Two layers of reuse close that gap:

* in-process: every builder below is ``lru_cache``d on its full build
  parameter tuple, so benches sharing a corpus share one build;
* cross-process (opt-in): set ``REPRO_BENCH_CACHE=<dir>`` and built
  indexes round-trip through the v2 segment manifest under a key derived
  from EVERY build parameter + the jax version — CI points all jobs at
  one ``actions/cache``d directory, so the dry index is built once per
  (params, jax) and restored everywhere else.  Corpora are regenerated
  (cheap, deterministic); only the k-means/quantize work is cached.
"""
from __future__ import annotations

import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_mod
from repro.data import synthetic as syn


def _cache_dir() -> str | None:
    return os.environ.get("REPRO_BENCH_CACHE") or None


def _cached_build(key: str, build_fn):
    """Disk-backed index build: v2-manifest round-trip under ``key``.

    The key must encode every parameter that changes the built arrays
    (plus the jax version — kernels move across releases); a cache hit is
    then array-identical to rebuilding by the builders' determinism.
    """
    root = _cache_dir()
    if root is None:
        return build_fn()
    path = os.path.join(root, f"{key}_jax{jax.__version__}")
    if os.path.isdir(path):
        try:
            from repro.live.manifest import load_segmented

            segments, *_ = load_segmented(path)
            if len(segments) == 1:
                return segments[0]
        except Exception:
            pass  # unreadable/foreign cache entry: rebuild and rewrite
    index = build_fn()
    from repro.build import emit

    os.makedirs(root, exist_ok=True)
    emit(index, path, layout="v2")
    return index


def corpus_and_index(n_docs: int, dim: int = 128, nbits: int = 2, seed: int = 0):
    docs, _topics, index = corpus_topics_and_index(n_docs, dim, nbits, seed)
    return docs, index


@functools.lru_cache(maxsize=6)
def corpus_topics_and_index(
    n_docs: int,
    dim: int = 128,
    nbits: int = 2,
    seed: int = 0,
    prune_fraction: float = 0.0,
    n_topics: int = 32,
):
    """Quality-harness fixture: keeps the topic labels (qrels need them)
    and exposes the build-time ``prune_fraction`` knob.  ``n_topics``
    controls qrels density (relevant docs per query ~ n_docs / n_topics) —
    the quality harness uses a LOW topic count so depth-k recall cannot
    saturate and the Pareto frontier stays multi-point at dry scale."""
    docs, topics = syn.embedding_corpus(
        n_docs, dim=dim, seed=seed, n_topics=n_topics
    )
    index = _cached_build(
        f"idx_n{n_docs}_d{dim}_b{nbits}_s{seed}_p{prune_fraction:g}"
        f"_t{n_topics}",
        lambda: index_mod.build_index(
            docs,
            nbits=nbits,
            kmeans_iters=4,
            seed=seed,
            prune_fraction=prune_fraction,
        ),
    )
    return docs, topics, index


def queries(docs, n: int, q_len: int = 16, seed: int = 1):
    qs, gold = syn.queries_from_docs(docs, n, q_len=q_len, seed=seed)
    return jnp.asarray(qs), gold


def scaled(n: int, dry: bool, floor: int = 1) -> int:
    """Dry-run scaling: ~1/16 of the configured size, at least ``floor``."""
    return max(floor, n // 16) if dry else n


def time_batched(fn, qs, batch: int = 16, trials: int = 3):
    """Paper protocol: average per-query latency, min over trials."""
    fn(qs[:batch])  # warmup/compile
    jax.block_until_ready(fn(qs[:batch]))
    best = float("inf")
    for _ in range(trials):
        t0 = time.perf_counter()
        for i in range(0, qs.shape[0], batch):
            out = fn(qs[i : i + batch])
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / qs.shape[0])
    return best * 1e3  # ms/query


def success_at_1(pids, gold) -> float:
    return float((np.asarray(pids)[:, 0] == gold).mean())


def recall_vs(pids, ref_pids, k: int) -> float:
    return float(
        np.mean(
            [
                len(set(np.asarray(p)[:k]) & set(np.asarray(r)[:k])) / k
                for p, r in zip(pids, ref_pids)
            ]
        )
    )
