"""Fig. 8 analog: scaling with parallelism degree.

The paper scales CPU threads; the TPU-native analog is scaling SHARDS of the
document-partitioned engine (DESIGN §3: thread-parallelism -> chip-
parallelism).  This container has ONE physical core, so wall-clock cannot
show the speedup; we report the quantities that determine it on real
hardware: per-shard work (candidates scored, tokens gathered — scales down
~1/n) and merge collective bytes (constant per query)."""
from __future__ import annotations

from repro import retrieval
from repro.core import engine_sharded

from benchmarks import common


def run(emit, dry: bool = False):
    docs, index = common.corpus_and_index(common.scaled(4000, dry, 500))
    sp = retrieval.SearchParams(
        k=100, nprobe=4, t_cs=0.4, ndocs=1024, candidate_cap=2048
    )
    for n_shards in (1, 2, 4, 8):
        idx_dict, meta, per = engine_sharded.shard_index(index, n_shards)
        # per-shard candidate cap shrinks with the shard's corpus slice
        # (same clamp the "plaid-sharded" backend applies)
        cap = min(sp.candidate_cap, max(per, 2))
        merge_bytes = n_shards * sp.k * 8  # (score f32 + pid i32) per shard
        emit(
            "fig8", f"shards{n_shards}",
            docs_per_shard=per,
            candidate_cap_per_shard=cap,
            tokens_gathered_per_query=cap * meta["doc_maxlen"],
            merge_collective_bytes_per_query=merge_bytes,
        )
