# One verify surface for this repo (see README "CI / verifying changes").
# Targets assume they run from the repo root.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast smoke ci

test:  ## tier-1: the full test suite
	$(PY) -m pytest -x -q

test-fast:  ## skip @pytest.mark.slow (arch smoke cells, multi-device subprocesses)
	$(PY) -m pytest -q -m "not slow"

smoke:  ## benchmark pipeline smoke run at dry scale (numbers not meaningful)
	$(PY) -m benchmarks.run --dry --only table3

ci: test smoke
