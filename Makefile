# One verify surface for this repo (see README "CI / verifying changes").
# Targets assume they run from the repo root.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast test-multidevice smoke bench-dry bench-diff \
	quality-sweep bench-quality-diff ci

test:  ## tier-1: the full test suite
	$(PY) -m pytest -x -q

test-fast:  ## skip @pytest.mark.slow (arch smoke cells, multi-device subprocesses)
	$(PY) -m pytest -q -m "not slow"

test-multidevice:  ## @pytest.mark.multidevice tests (sharded-live grid etc.)
	## on 4 fake in-process devices; these skip in the plain `make test` run
	XLA_FLAGS="--xla_force_host_platform_device_count=4" $(PY) -m pytest -q -m multidevice

smoke:  ## quickest benchmark pipeline smoke (table3 only)
	$(PY) -m benchmarks.run --dry --only table3

bench-dry:  ## EVERY registered benchmark at dry scale (incl. live_ingest):
	## catches benchmark registration breakage before merge.  CI passes
	## BENCH_FLAGS="--json BENCH_dry.json --trace trace_dry.json"; bare
	## filenames land under the gitignored out/ directory, and CI uploads
	## the results + the Chrome-trace span export from there.
	$(PY) -m benchmarks.run --dry $(BENCH_FLAGS)

bench-diff:  ## gate per-kernel hbm_bytes against the committed baseline
	## (>15% growth, vanished kernels, fused >= unfused, or tiered
	## transfer >= resident payload all fail); CURRENT defaults to the
	## bench-dry artifact under out/.
	$(PY) -m benchmarks.bench_diff BENCH_seed.json $(or $(CURRENT),out/BENCH_dry.json)

quality-sweep:  ## retrieval-quality harness at dry scale: Pareto sweep +
	## lossless-caps certification of every backend/approximation (exits
	## nonzero on any recall@10 drop > 1e-6 vs the exact f32 baseline) +
	## pruned-build footprint/quality trade.  Writes the schema-v3 quality
	## payload and the frontier CSV under out/.
	$(PY) -m benchmarks.quality_sweep --dry \
		--json out/BENCH_quality.json --csv out/pareto_quality.csv

bench-quality-diff:  ## gate the (work, recall@10) Pareto frontier against
	## the committed quality baseline: any committed frontier point the
	## current run can no longer match at comparable work fails.
	$(PY) -m benchmarks.bench_diff BENCH_quality_seed.json \
		$(or $(QUALITY_CURRENT),out/BENCH_quality.json)

# The GitHub workflow runs these targets as PARALLEL jobs (tests /
# multidevice / bench-dry / quality); `make ci` remains the serial local
# equivalent.
ci: test test-multidevice bench-dry quality-sweep bench-quality-diff
