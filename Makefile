# One verify surface for this repo (see README "CI / verifying changes").
# Targets assume they run from the repo root.
PY ?= python
export PYTHONPATH := src

.PHONY: test test-fast smoke bench-dry ci

test:  ## tier-1: the full test suite
	$(PY) -m pytest -x -q

test-fast:  ## skip @pytest.mark.slow (arch smoke cells, multi-device subprocesses)
	$(PY) -m pytest -q -m "not slow"

smoke:  ## quickest benchmark pipeline smoke (table3 only)
	$(PY) -m benchmarks.run --dry --only table3

bench-dry:  ## EVERY registered benchmark at dry scale (incl. live_ingest):
	## catches benchmark registration breakage before merge
	$(PY) -m benchmarks.run --dry

ci: test bench-dry
