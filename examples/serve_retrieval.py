"""End-to-end serving driver (the paper's deployment shape):

  ColBERT encoder -> offline corpus encoding -> PLAID index build ->
  batched online retrieval with latency percentiles + vanilla comparison.

    PYTHONPATH=src python examples/serve_retrieval.py [--docs 3000]

Reduced-scale encoder by default (CPU container); pass --full for the
BERT-base-class config on real hardware.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import retrieval
from repro.configs import colbertv2 as colbert_cfg
from repro.core import index as index_mod
from repro.models import colbert as colbert_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--docs", type=int, default=3000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = colbert_cfg.full_config() if args.full else colbert_cfg.reduced_config()
    params = colbert_lib.init_params(jax.random.PRNGKey(0), cfg)
    vocab = cfg.backbone.vocab
    rng = np.random.default_rng(0)

    # --- offline: encode the corpus (batched) and build the index
    d_len = 24
    corpus_tokens = rng.integers(0, vocab, (args.docs, d_len)).astype(np.int32)
    encode = jax.jit(lambda t: colbert_lib.encode(params, cfg, t))
    t0 = time.perf_counter()
    embs = []
    for i in range(0, args.docs, 256):
        embs.append(np.asarray(encode(jnp.asarray(corpus_tokens[i : i + 256]))))
    embs = np.concatenate(embs)
    print(f"encoded {args.docs} passages in {time.perf_counter()-t0:.1f}s")
    index = index_mod.build_index(
        embs.reshape(-1, cfg.out_dim),
        doc_lens=np.full(args.docs, d_len, np.int32),
    )
    print(f"index: {index.num_tokens} tokens, {index.num_centroids} centroids")

    # --- online: queries are prefixes of corpus passages (gold = source doc)
    q_len = 8
    gold = rng.integers(0, args.docs, args.queries)
    q_tokens = corpus_tokens[gold][:, :q_len]
    q_embs = np.asarray(encode(jnp.asarray(q_tokens)))

    searcher = retrieval.from_index(
        index, backend="plaid", params=retrieval.params_for_k(args.k)
    )
    qs = jnp.asarray(q_embs)
    searcher.search_batch(qs[:16]).pids.block_until_ready()  # compile
    lat = []
    all_pids = []
    for i in range(0, args.queries, 16):
        chunk = qs[i : i + 16]
        t0 = time.perf_counter()
        res = searcher.search_batch(chunk)
        res.pids.block_until_ready()
        lat.append((time.perf_counter() - t0) / len(chunk) * 1e3)
        all_pids.append(np.asarray(res.pids))
    all_pids = np.concatenate(all_pids)
    print(
        f"PLAID k={args.k}: {np.mean(lat):.2f} ms/q "
        f"(p99 {np.percentile(lat, 99):.2f})"
    )

    vs = retrieval.from_index(
        index,
        backend="vanilla",
        params=retrieval.SearchParams(
            k=args.k, nprobe=4, candidate_cap=4096, ndocs=4096
        ),
    )
    v_pids0 = vs.search_batch(qs[:16]).pids
    v_pids0.block_until_ready()
    t0 = time.perf_counter()
    _, v_pids = vs.search_batch(qs)
    v_pids.block_until_ready()
    v_ms = (time.perf_counter() - t0) / args.queries * 1e3
    # engine fidelity: agreement of PLAID's top-1 with the vanilla baseline
    # (a randomly-initialized encoder has no retrieval QUALITY — train it
    # with examples/train_colbert.py — but the ENGINE must agree with the
    # exhaustive-ish baseline on whatever geometry the encoder produces)
    agree = (all_pids[:, 0] == np.asarray(v_pids)[:, 0]).mean()
    print(
        f"vanilla: {v_ms:.2f} ms/q -> PLAID speedup {v_ms/np.mean(lat):.1f}x, "
        f"top-1 agreement {agree:.0%}"
    )


if __name__ == "__main__":
    main()
