"""Quickstart: build a PLAID index and search it, in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_mod
from repro.core.plaid import PlaidSearcher, params_for_k
from repro.data.synthetic import embedding_corpus, queries_from_docs

# 1. a corpus of token-level embedding matrices (one per passage) — in a real
#    deployment these come from the ColBERT encoder (examples/serve_retrieval.py)
docs, _ = embedding_corpus(n_docs=5000, dim=128, seed=0)

# 2. index it: k-means centroids + 2-bit residual compression + centroid->pid IVF
index = index_mod.build_index(docs, nbits=2)
print(
    f"index: {index.num_passages} passages, {index.num_tokens} tokens, "
    f"{index.num_centroids} centroids"
)

# 3. search with the PLAID 4-stage pipeline (paper Table 2 settings for k=10)
searcher = PlaidSearcher(index, params_for_k(10))
queries, gold = queries_from_docs(docs, n_queries=16)
scores, pids = searcher.search_batch(jnp.asarray(queries))

hits = (np.asarray(pids[:, 0]) == gold).mean()
print(f"top-1 = gold passage for {hits:.0%} of queries")
print("first query top-5:", np.asarray(pids[0][:5]), np.asarray(scores[0][:5]).round(3))
