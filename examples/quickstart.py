"""Quickstart: build, search, tune, and persist a retriever via the facade.

    PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import retrieval
from repro.data.synthetic import embedding_corpus, queries_from_docs

# 1. a corpus of token-level embedding matrices (one per passage) — in a real
#    deployment these come from the ColBERT encoder (examples/serve_retrieval.py)
docs, _ = embedding_corpus(n_docs=5000, dim=128, seed=0)

# 2. one call: k-means centroids + 2-bit residual compression + IVF + engine.
#    Backends: "vanilla" | "plaid" | "plaid-pallas" | "plaid-sharded"
searcher = retrieval.build(docs, backend="plaid",
                           params=retrieval.params_for_k(10))
print({k: v for k, v in searcher.describe()["index"].items()})

# 3. search with the PLAID 4-stage pipeline (paper Table 2 settings for k=10)
queries, gold = queries_from_docs(docs, n_queries=16)
res = searcher.search_batch(jnp.asarray(queries))
hits = (np.asarray(res.pids[:, 0]) == gold).mean()
print(f"top-1 = gold passage for {hits:.0%} of queries  "
      f"({res.latency_ms / 16:.2f} ms/query, backend={res.backend})")

# 4. tune pruning per request: t_cs is a traced scalar, so sweeping it reuses
#    the compiled program (zero recompiles — check describe()["compile"])
for t_cs in (0.3, 0.5, 0.6):
    r = searcher.search_batch(jnp.asarray(queries), t_cs=t_cs)
    print(f"t_cs={t_cs}: top-1 {np.mean(np.asarray(r.pids[:, 0]) == gold):.0%}")

# 5. persist and restore — retrieval.load reads the backend from disk
with tempfile.TemporaryDirectory() as d:
    searcher.save(d)
    restored = retrieval.load(d)
    r = restored.search(jnp.asarray(queries[0]))
    print("restored", restored.backend_name, "top-5:", np.asarray(r.pids[:5]))
