"""Live-index walkthrough: mutate a serving corpus with zero downtime.

    PYTHONPATH=src python examples/live_ingest.py

Covers the full lifecycle: build a base index, stream new passages in as
delta segments (encoded against the base's FROZEN centroids + codec — no
re-clustering), tombstone deletes, background compaction, the buffered
IndexWriter, mutation while a BatchingServer is taking queries, and the
v2 segment-manifest save/load round-trip.
"""
import tempfile

import jax.numpy as jnp
import numpy as np

from repro import live, retrieval
from repro.data.synthetic import embedding_corpus, queries_from_docs

# 1. a starting corpus, served by the mutable "live" backend
docs, _ = embedding_corpus(n_docs=3000, dim=128, seed=0)
r = retrieval.build(docs[:2000], backend="live",
                    params=retrieval.params_for_k(10))
print("base:", {k: r.describe()["index"][k]
                for k in ("num_passages", "num_segments")})

# 2. stream the rest of the corpus in WHILE queries keep flowing — each
#    add_passages call becomes one delta segment; no k-means, no downtime
queries, gold = queries_from_docs(docs, n_queries=16)
pids_a = r.add_passages(docs[2000:2500])
pids_b = r.add_passages(docs[2500:])
res = r.search_batch(jnp.asarray(queries))
hits = (np.asarray(res.pids[:, 0]) == gold).mean()
print(f"after ingest: top-1 = gold for {hits:.0%} of queries, "
      f"{r.describe()['index']['num_deltas']} delta segments")

# 3. deletes are tombstones: no array rewrite, results exclude them at once
victim = int(np.asarray(res.pids[0, 0]))
r.delete_passages([victim])
res2 = r.search(jnp.asarray(queries[0]))
assert victim not in np.asarray(res2.pids)
print(f"deleted pid {victim}: gone from results, "
      f"{r.describe()['index']['num_deleted']} tombstoned")

# 4. buffered ingest for high-rate streams: IndexWriter coalesces adds
#    into one segment per flush (fewer segments = fewer per-query launches)
more, _ = embedding_corpus(n_docs=300, dim=128, seed=7)
with r.writer(flush_every=256) as w:
    for d in more:
        w.add(d)            # auto-flushes every 256 passages
print("after writer:", r.describe()["index"]["num_deltas"], "deltas")

# 5. compaction merges deltas into the base and drops tombstones —
#    run it in the background with a Compactor, or on demand:
pid_map = r.compact()       # old global pid -> new pid (-1 = dropped)
print("compacted:", {k: r.describe()["index"][k]
                     for k in ("num_segments", "num_passages")},
      f"(pid {victim} -> {pid_map[victim]})")

# 6. mutate while a BatchingServer is live: snapshots keep in-flight
#    batches consistent, the next batch sees the new corpus
from repro.serving.server import BatchingServer

srv = BatchingServer(r, batch_size=8, max_wait_ms=2.0)
try:
    futs = [srv.submit(np.asarray(q)) for q in queries]
    srv.add_passages(list(embedding_corpus(n_docs=64, dim=128, seed=9)[0]))
    print("served", len([f.get(timeout=60) for f in futs]),
          "queries during ingest; stats:", srv.stats()["n"])
finally:
    srv.shutdown()

# 7. persistence: the v2 segment manifest round-trips segments, tombstones
#    and the generation counter behind an atomic manifest swap
with tempfile.TemporaryDirectory() as d:
    r.save(d)
    r2 = retrieval.load(d)   # backend "live" restored from disk
    print("restored:", r2.backend_name, r2.describe()["index"]["num_passages"],
          "passages, generation", r2.describe()["index"]["generation"])
