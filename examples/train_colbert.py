"""End-to-end ColBERTv2 training driver: contrastive + distillation loss,
AdamW, grad accumulation, checkpointing, fault-tolerant supervision.

Reduced scale on CPU (a few hundred steps run in minutes); ``--full`` uses
the ~110M BERT-base-class config for real hardware:

    PYTHONPATH=src python examples/train_colbert.py --steps 200
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import colbertv2 as colbert_cfg
from repro.data.synthetic import colbert_batches
from repro.models import colbert as colbert_lib
from repro.training import fault_tolerance as ft
from repro.training import loop as train_loop
from repro.training import optimizer as opt_lib


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--n-micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/colbert_ckpt")
    args = ap.parse_args()

    cfg = colbert_cfg.full_config() if args.full else colbert_cfg.reduced_config()
    params = colbert_lib.init_params(jax.random.PRNGKey(0), cfg)
    n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
    print(f"ColBERT encoder: {n_params:,} params (out_dim={cfg.out_dim})")

    optimizer = opt_lib.adamw(
        opt_lib.AdamWConfig(
            schedule=opt_lib.cosine_schedule(args.lr, 20, args.steps)
        )
    )
    step = jax.jit(
        train_loop.make_train_step(
            lambda p, b: colbert_lib.train_loss(p, cfg, b),
            optimizer,
            n_micro=args.n_micro,
        ),
        donate_argnums=(0, 1),
    )
    opt_state = optimizer.init(params)
    it = colbert_batches(
        cfg.backbone.vocab, args.batch, q_len=8, d_len=16, nway=cfg.nway
    )

    losses = []
    watchdog = ft.StepWatchdog()

    def step_fn(state, batch):
        p, o, m = step(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        return {"params": p, "opt": o}

    batches = (
        {k: jnp.asarray(v) for k, v in next(it).items()}
        for _ in range(args.steps)
    )
    t0 = time.perf_counter()
    state, final, restarts = ft.run_supervised(
        step_fn,
        {"params": params, "opt": opt_state},
        batches,
        ckpt_dir=args.ckpt_dir,
        ckpt_every=100,
        watchdog=watchdog,
    )
    dt = time.perf_counter() - t0
    print(
        f"{final} steps in {dt:.1f}s ({dt/final*1e3:.0f} ms/step), "
        f"restarts={restarts}, stragglers={len(watchdog.stragglers)}"
    )
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-10:]):.3f}")
    assert np.mean(losses[-10:]) < losses[0]


if __name__ == "__main__":
    main()
